//! Ablations over the design choices DESIGN.md calls out.
//!
//! 1. Small-key dense path vs generic hash path (isolates §2.3.3): π with
//!    a `Vec` target (dense) vs a `DistHashMap` target (generic eager).
//! 2. Thread-local cache capacity sweep (the "popular keys" cache,
//!    §2.3.1): word-count shuffle volume and host time vs cache size.
//! 3. L2 fusion: fused single-MapReduce GMM E-step vs the paper's literal
//!    6-MapReduce decomposition.
//! 4. Allocator (Blaze vs Blaze-TCM): pool hit rates and host-time delta.
//! 5. Backpressure window sweep: peak in-flight shuffle bytes.
//!
//! Every ablation also appends its datapoints (including run counters
//! where a cluster run is involved) to `BENCH_ablations.json` via
//! [`bench::report`].

use blaze::apps::gmm;
use blaze::bench;
use blaze::containers::{DistHashMap, DistRange, DistVector};
use blaze::coordinator::cluster::{Cluster, ClusterConfig};
use blaze::data::{corpus_lines, PointSet};
use blaze::mapreduce::{mapreduce_range_labeled, mapreduce_labeled};
use blaze::util::alloc::AllocMode;
use blaze::util::rng::SplitRng;

use blaze::bench::report::{Report, Row};

fn ablation_dense_vs_hash(rep: &mut Report) {
    println!("--- ablation 1: small-key dense path vs generic hash path (pi) ---");
    let n = 2_000_000 * bench::scale() as u64;
    let reps = bench::reps();
    let dense = bench::time_host(reps, || {
        let c = Cluster::local(1, 4);
        let samples = DistRange::new(&c, 0, n);
        let mut count = vec![0u64; 1];
        let rng = std::cell::RefCell::new(SplitRng::new(1, 0));
        mapreduce_range_labeled(
            "abl.dense",
            &samples,
            |_, emit| {
                let mut r = rng.borrow_mut();
                let (x, y) = (r.uniform(), r.uniform());
                if x * x + y * y < 1.0 {
                    emit(0usize, 1u64);
                }
            },
            "sum",
            &mut count,
        );
        count[0]
    });
    let hash = bench::time_host(reps, || {
        let c = Cluster::local(1, 4);
        let samples = DistRange::new(&c, 0, n);
        let mut count: DistHashMap<usize, u64> = DistHashMap::new(&c);
        let rng = std::cell::RefCell::new(SplitRng::new(1, 0));
        mapreduce_range_labeled(
            "abl.hash",
            &samples,
            |_, emit| {
                let mut r = rng.borrow_mut();
                let (x, y) = (r.uniform(), r.uniform());
                if x * x + y * y < 1.0 {
                    emit(0usize, 1u64);
                }
            },
            "sum",
            &mut count,
        );
        count.get(&0)
    });
    for (variant, s) in [("dense", &dense), ("hash", &hash)] {
        rep.push(
            Row::new("dense-vs-hash")
                .tag("variant", variant)
                .num("host_wall_mean_sec", s.mean)
                .num("host_wall_std_sec", s.std),
        );
    }
    println!(
        "  dense {:>10}s   hash {:>10}s   dense is {:.2}x faster\n",
        dense, hash, hash.mean / dense.mean
    );
}

fn ablation_cache_sweep(rep: &mut Report) {
    println!("--- ablation 2: thread-local cache capacity (wordcount) ---");
    let lines = corpus_lines(30_000 * bench::scale(), 10, 42);
    println!(
        "  {:>10} {:>16} {:>14} {:>12}",
        "cache", "pairs shuffled", "shuffle bytes", "host (s)"
    );
    for cache in [16usize, 256, 4096, 65_536, 1 << 20] {
        let mut cfg = ClusterConfig::sized(4, 4);
        cfg.thread_cache_entries = cache;
        let c = Cluster::new(cfg);
        let dv = DistVector::from_vec(&c, lines.clone());
        let mut words: DistHashMap<String, u64> = DistHashMap::new(&c);
        let t0 = std::time::Instant::now();
        mapreduce_labeled(
            "abl.cache",
            &dv,
            |_, line: &String, emit| {
                for w in line.split_whitespace() {
                    emit(w.to_string(), 1u64);
                }
            },
            "sum",
            &mut words,
        );
        let host = t0.elapsed().as_secs_f64();
        let m = c.metrics();
        let run = m.last_run().unwrap();
        rep.push(
            Row::new("cache-sweep")
                .tag("cache_entries", cache)
                .num("pairs_shuffled", run.pairs_shuffled as f64)
                .num("shuffle_bytes", run.shuffle_bytes as f64)
                .num("host_wall_sec", host)
                .counters(run),
        );
        println!(
            "  {:>10} {:>16} {:>14} {:>12.4}",
            cache, run.pairs_shuffled, run.shuffle_bytes, host
        );
    }
    println!();
}

fn ablation_fused_vs_six_mr(rep: &mut Report) {
    println!("--- ablation 3: fused GMM E-step vs paper's 6-MapReduce structure ---");
    let ps = PointSet::clustered(6_000 * bench::scale(), 3, 4, 0.5, 9);
    let init = gmm::GmmModel::init(&ps.true_centers.clone(), 4, 3);
    let reps = bench::reps();
    let fused = bench::time_host(reps, || {
        let c = Cluster::local(4, 4);
        let blocks = blaze::apps::kmeans::distribute_blocks(&c, &ps, 512);
        gmm::gmm_fused(&c, &blocks, ps.n, ps.dim, init.clone(), 0.0, 3, None).1.loglik
    });
    let six = bench::time_host(reps, || {
        let c = Cluster::local(4, 4);
        gmm::gmm_paper_structured(&c, &ps, init.clone(), 0.0, 3).1.loglik
    });
    for (variant, s) in [("fused", &fused), ("six-mr", &six)] {
        rep.push(
            Row::new("l2-fusion")
                .tag("variant", variant)
                .num("host_wall_mean_sec", s.mean)
                .num("host_wall_std_sec", s.std),
        );
    }
    println!(
        "  fused {:>10}s   6-MR {:>10}s   fusion is {:.2}x faster (host)\n",
        fused, six, six.mean / fused.mean
    );
}

fn ablation_allocator(rep: &mut Report) {
    println!("--- ablation 4: allocator (Blaze vs Blaze-TCM pool) ---");
    let lines = corpus_lines(30_000 * bench::scale(), 10, 42);
    let reps = bench::reps();
    for alloc in [AllocMode::System, AllocMode::Pool] {
        let cluster = Cluster::new(ClusterConfig::sized(4, 4).with_alloc(alloc));
        let sample = bench::time_host(reps, || {
            let dv = DistVector::from_vec(&cluster, lines.clone());
            let mut words: DistHashMap<String, u64> = DistHashMap::new(&cluster);
            mapreduce_labeled(
                "abl.alloc",
                &dv,
                |_, line: &String, emit| {
                    for w in line.split_whitespace() {
                        emit(w.to_string(), 1u64);
                    }
                },
                "sum",
                &mut words,
            );
            words.len()
        });
        let (hits, misses) = cluster.pool().stats();
        let mut row = Row::new("allocator")
            .tag("alloc", alloc)
            .num("host_wall_mean_sec", sample.mean)
            .num("host_wall_std_sec", sample.std)
            .num("pool_hits", hits as f64)
            .num("pool_misses", misses as f64);
        if let Some(run) = cluster.metrics().last_run() {
            row = row.counters(run);
        }
        rep.push(row);
        println!(
            "  {:<10} host {:>10}s   pool hits/misses {}/{}",
            alloc.to_string(),
            sample,
            hits,
            misses
        );
    }
    println!("  (paper: throughput difference negligible; unlinked variance higher)\n");
}

fn ablation_backpressure(rep: &mut Report) {
    println!("--- ablation 5: backpressure window vs peak in-flight bytes ---");
    use blaze::coordinator::shuffle;
    let payload_count = 64;
    let payload_bytes = 256 * 1024;
    println!("  {:>12} {:>18} {:>8}", "window", "peak in-flight", "stalls");
    for window in [64 * 1024u64, 1 << 20, 4 << 20, u64::MAX] {
        let payloads: Vec<Vec<Vec<u8>>> = (0..2)
            .map(|src| {
                (0..2)
                    .map(|dst| {
                        if src == 0 && dst == 1 {
                            vec![0u8; payload_bytes * payload_count]
                        } else {
                            Vec::new()
                        }
                    })
                    .collect()
            })
            .collect();
        let res = shuffle::execute(payloads, window);
        rep.push(
            Row::new("backpressure")
                .tag("window", if window == u64::MAX { "unbounded".into() } else { window.to_string() })
                .num("peak_in_flight_bytes", res.peak_in_flight_bytes as f64)
                .num("stalls", res.stalls as f64),
        );
        println!(
            "  {:>12} {:>18} {:>8}",
            if window == u64::MAX { "unbounded".to_string() } else { blaze::bench::fmt_bytes(window) },
            blaze::bench::fmt_bytes(res.peak_in_flight_bytes),
            res.stalls
        );
    }
    println!();
}

fn ablation_cross_rack(rep: &mut Report) {
    println!("--- ablation 6: cross-rack bottleneck (paper 2.3.2 scaling claim) ---");
    // "The smaller size in the serialized message means less network
    // traffics, so that Blaze can scale better on large clusters when the
    // cross-rack bandwidth becomes the bottleneck." Sweep a bisection cap
    // on a 16-node word count and compare engines.
    use blaze::coordinator::cluster::EngineKind;
    use blaze::net::model::NetworkModel;
    let lines = corpus_lines(30_000 * bench::scale(), 10, 42);
    let n_words: u64 = lines.iter().map(|l| l.split_whitespace().count() as u64).sum();
    println!(
        "  {:>14} {:>16} {:>16} {:>9}",
        "bisection", "blaze (w/s)", "conv (w/s)", "speedup"
    );
    for bisection_gbps in [f64::INFINITY, 40.0, 10.0, 2.5] {
        let network = if bisection_gbps.is_infinite() {
            NetworkModel::aws_10gbps()
        } else {
            NetworkModel::aws_10gbps_cross_rack(bisection_gbps)
        };
        let run = |engine: EngineKind| {
            let c = Cluster::new(
                ClusterConfig::sized(16, 4).with_engine(engine).with_network(network),
            );
            let dv = DistVector::from_vec(&c, lines.clone());
            let report = blaze::apps::wordcount::wordcount(&c, &dv).0;
            n_words as f64 / report.makespan_sec
        };
        let blaze = run(EngineKind::Eager);
        let conv = run(EngineKind::Conventional);
        rep.push(
            Row::new("cross-rack")
                .tag(
                    "bisection_gbps",
                    if bisection_gbps.is_infinite() {
                        "uncapped".to_string()
                    } else {
                        bisection_gbps.to_string()
                    },
                )
                .num("blaze_words_per_sec", blaze)
                .num("conv_words_per_sec", conv),
        );
        println!(
            "  {:>14} {:>16.0} {:>16.0} {:>8.1}x",
            if bisection_gbps.is_infinite() {
                "uncapped".to_string()
            } else {
                format!("{bisection_gbps} Gbps")
            },
            blaze,
            conv,
            blaze / conv
        );
    }
    println!(
        "  (the cap binds both engines; eager's ~9x smaller shuffle keeps it \
         an order of magnitude ahead at every bisection)\n"
    );
}

fn main() {
    bench::figure_header(
        "Design-choice ablations",
        "dense path, eager cache size, L2 fusion, allocator, backpressure, cross-rack",
    );
    let mut rep = Report::new("ablations");
    rep.meta("scale", bench::scale());
    rep.meta("reps", bench::reps());
    ablation_dense_vs_hash(&mut rep);
    ablation_cache_sweep(&mut rep);
    ablation_fused_vs_six_mr(&mut rep);
    ablation_allocator(&mut rep);
    ablation_backpressure(&mut rep);
    ablation_cross_rack(&mut rep);
    match rep.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write bench json: {e}"),
    }
}
