//! Figure 4: word frequency count — words/second vs node count.
//!
//! Paper: Blaze > 10x Spark across 1–16 r5.xlarge nodes; "Blaze TCM"
//! (TCMalloc) ≈ Blaze. Series here: blaze, blaze-tcm (pool allocator),
//! conventional (Spark analog). Throughput is computed from the virtual
//! makespan (measured per-node compute + modeled 10 Gbps interconnect).
//!
//! `--backend threaded:N` (or `BLAZE_BACKEND`) runs the blaze series'
//! map+combine on N real OS threads; the conventional baseline always
//! runs simulated. Besides the printed table, every run appends the
//! datapoints — virtual makespan, real wall-clock fields, and the
//! per-run counter registry — to `BENCH_fig4_wordcount.json` via
//! [`bench::report`]. `--trace PATH` (or `BLAZE_TRACE`) additionally
//! exports the blaze series' structured event log per node count
//! (`PATH.n<nodes>` + its Chrome view).

use blaze::apps::wordcount::wordcount;
use blaze::bench;
use blaze::coordinator::cluster::{Cluster, ClusterConfig, EngineKind};
use blaze::prelude::*;
use blaze::util::alloc::AllocMode;

struct Point {
    throughput: f64,
    makespan_sec: f64,
    stats: blaze::coordinator::metrics::RunStats,
}

fn main() {
    bench::figure_header(
        "Figure 4: Word Frequency Count (words/second)",
        "Blaze ~10x Spark; Blaze TCM ~= Blaze; near-linear node scaling",
    );
    let backend = bench::backend();
    let scale = bench::scale();
    let trace = bench::trace_path();
    let lines = blaze::data::corpus_lines(40_000 * scale, 10, 42);
    let n_words: u64 = lines.iter().map(|l| l.split_whitespace().count() as u64).sum();
    println!("corpus: {} lines, {} words, backend {backend}\n", lines.len(), n_words);

    let mut rep = bench::report::Report::new("fig4_wordcount");
    rep.meta("backend", backend);
    rep.meta("scale", scale);
    rep.meta("corpus_words", n_words);

    println!(
        "{:<6} {:>16} {:>16} {:>16} {:>9}",
        "nodes", "blaze (w/s)", "blaze-tcm (w/s)", "conv (w/s)", "speedup"
    );
    for nodes in bench::node_sweep() {
        let run = |engine: EngineKind, alloc: AllocMode, backend: Backend, trace_to: Option<String>| {
            let c = Cluster::new(
                ClusterConfig::sized(nodes, 4)
                    .with_engine(engine)
                    .with_alloc(alloc)
                    .with_backend(backend)
                    .with_trace(trace_to.is_some()),
            );
            let dv = DistVector::from_vec(&c, lines.clone());
            let report = wordcount(&c, &dv).0;
            if let Some(path) = trace_to {
                match c.export_trace(&path) {
                    Ok(()) => println!("trace written: {path}"),
                    Err(e) => eprintln!("trace export to {path:?} failed: {e}"),
                }
            }
            let metrics = c.metrics();
            let last = metrics.last_run().expect("wordcount records a run");
            Point {
                throughput: report.throughput,
                makespan_sec: report.makespan_sec,
                stats: last.clone(),
            }
        };
        // Only the blaze series is traced (one log per node count).
        let blaze = run(
            EngineKind::Eager,
            AllocMode::System,
            backend,
            trace.as_ref().map(|base| format!("{base}.n{nodes}")),
        );
        let tcm = run(EngineKind::Eager, AllocMode::Pool, backend, None);
        // The conventional baseline models Spark; always simulated.
        let conv = run(EngineKind::Conventional, AllocMode::System, Backend::Simulated, None);
        for (series, p) in [("blaze", &blaze), ("blaze-tcm", &tcm), ("conventional", &conv)] {
            rep.push(
                bench::report::Row::new(series)
                    .tag("nodes", nodes)
                    .num("words_per_sec", p.throughput)
                    .num("virtual_makespan_sec", p.makespan_sec)
                    .num("host_wall_sec", p.stats.host_wall_sec)
                    .num("wall_ns", p.stats.wall_ns_total() as f64)
                    .counters(&p.stats),
            );
        }
        println!(
            "{:<6} {:>16.0} {:>16.0} {:>16.0} {:>8.1}x",
            nodes,
            blaze.throughput,
            tcm.throughput,
            conv.throughput,
            blaze.throughput / conv.throughput
        );
    }

    match rep.write() {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\ncould not write bench json: {e}"),
    }
}
