//! Figure 4: word frequency count — words/second vs node count.
//!
//! Paper: Blaze > 10x Spark across 1–16 r5.xlarge nodes; "Blaze TCM"
//! (TCMalloc) ≈ Blaze. Series here: blaze, blaze-tcm (pool allocator),
//! conventional (Spark analog). Throughput is computed from the virtual
//! makespan (measured per-node compute + modeled 10 Gbps interconnect).

use blaze::apps::wordcount::wordcount;
use blaze::bench;
use blaze::coordinator::cluster::{Cluster, ClusterConfig, EngineKind};
use blaze::prelude::*;
use blaze::util::alloc::AllocMode;

fn main() {
    bench::figure_header(
        "Figure 4: Word Frequency Count (words/second)",
        "Blaze ~10x Spark; Blaze TCM ~= Blaze; near-linear node scaling",
    );
    let scale = bench::scale();
    let lines = blaze::data::corpus_lines(40_000 * scale, 10, 42);
    let n_words: u64 = lines.iter().map(|l| l.split_whitespace().count() as u64).sum();
    println!("corpus: {} lines, {} words\n", lines.len(), n_words);

    println!(
        "{:<6} {:>16} {:>16} {:>16} {:>9}",
        "nodes", "blaze (w/s)", "blaze-tcm (w/s)", "conv (w/s)", "speedup"
    );
    for nodes in bench::node_sweep() {
        let run = |engine: EngineKind, alloc: AllocMode| {
            let c = Cluster::new(
                ClusterConfig::sized(nodes, 4).with_engine(engine).with_alloc(alloc),
            );
            let dv = DistVector::from_vec(&c, lines.clone());
            wordcount(&c, &dv).0.throughput
        };
        let blaze = run(EngineKind::Eager, AllocMode::System);
        let tcm = run(EngineKind::Eager, AllocMode::Pool);
        let conv = run(EngineKind::Conventional, AllocMode::System);
        println!(
            "{:<6} {:>16.0} {:>16.0} {:>16.0} {:>8.1}x",
            nodes,
            blaze,
            tcm,
            conv,
            blaze / conv
        );
    }
}
