//! Figure 9: peak memory usage on a single node, per task.
//!
//! Paper: Spark uses ~10x Blaze's memory on PageRank / K-Means / GMM
//! (intermediate pair materialization); k-NN is the one task where they are
//! close (no intermediate pairs). Blaze TCM is the same order of magnitude
//! as Blaze. Peak bytes here are the engines' intermediate-state
//! accounting: thread caches + materialized pair buffers + in-flight
//! serialized blocks (see `coordinator::metrics`). Datapoints (peak
//! bytes, last-run counters) append to `BENCH_fig9_memory.json` via
//! [`bench::report`].

use blaze::apps::{gmm, kmeans, knn, pagerank, wordcount};
use blaze::bench::{self, fmt_bytes};
use blaze::coordinator::cluster::{Cluster, ClusterConfig, EngineKind};
use blaze::data::{corpus_lines, Graph, PointSet};
use blaze::prelude::*;
use blaze::runtime::Runtime;
use blaze::util::alloc::AllocMode;

fn main() {
    bench::figure_header(
        "Figure 9: Peak memory usage on a single node",
        "Spark ~10x Blaze on PageRank/K-Means/GMM; close on k-NN; TCM same order",
    );
    let runtime = Runtime::load("artifacts").ok();
    let (dim, k) = runtime.as_ref().map_or((4, 5), |rt| (rt.dim(), rt.k()));
    let batch = runtime.as_ref().map_or(4096, Runtime::batch);
    let scale = bench::scale();

    let lines = corpus_lines(40_000 * scale, 10, 42);
    let graph = Graph::graph500(12 + scale.ilog2(), 16, 42);
    let km = PointSet::clustered(60_000 * scale, dim, k, 0.6, 42);
    let gm = PointSet::clustered(12_000 * scale, dim, k, 0.6, 43);
    let nn = PointSet::uniform(120_000 * scale, dim, 44);
    let query = vec![0.5f32; dim];

    // Single local node, 12 workers like the paper's 12-logical-core box.
    let mk = |engine: EngineKind, alloc: AllocMode| {
        Cluster::new(ClusterConfig::sized(1, 12).with_engine(engine).with_alloc(alloc))
    };

    let peak = |c: &Cluster, prefix: &str| c.metrics().job_peak_bytes(prefix);

    let mut rep = bench::report::Report::new("fig9_memory");
    rep.meta("scale", scale);
    rep.meta("pjrt", runtime.is_some());

    println!(
        "{:<10} {:>14} {:>14} {:>14} {:>8}",
        "task", "blaze", "blaze-tcm", "conventional", "ratio"
    );
    let configs = [
        ("blaze", EngineKind::Eager, AllocMode::System),
        ("blaze-tcm", EngineKind::Eager, AllocMode::Pool),
        ("conventional", EngineKind::Conventional, AllocMode::System),
    ];
    for task in ["wordcount", "pagerank", "kmeans", "gmm", "knn"] {
        let mut peaks = [0u64; 3];
        for (i, &(series, engine, alloc)) in configs.iter().enumerate() {
            let c = mk(engine, alloc);
            peaks[i] = match task {
                "wordcount" => {
                    let dv = DistVector::from_vec(&c, lines.clone());
                    wordcount::wordcount(&c, &dv);
                    peak(&c, "wordcount.")
                }
                "pagerank" => {
                    pagerank::pagerank(&c, &graph, 1e-5, 15);
                    peak(&c, "pagerank.")
                }
                "kmeans" => {
                    let blocks = kmeans::distribute_blocks(&c, &km, batch);
                    let init = kmeans::init_first_k(&km, k);
                    kmeans::kmeans(&c, &blocks, km.n, dim, k, init, 1e-4, 10, runtime.as_ref());
                    peak(&c, "kmeans.")
                }
                "gmm" => {
                    gmm::gmm_from_points(&c, &gm, k, 1e-6, 8, runtime.as_ref());
                    peak(&c, "gmm.")
                }
                "knn" => {
                    knn::knn(&c, &nn, &query, 100, runtime.as_ref());
                    // k-NN peak: candidate (dist, idx) vector + top-k heaps.
                    peak(&c, "knn.").max((nn.n * std::mem::size_of::<(f32, u32)>()) as u64)
                }
                _ => unreachable!(),
            };
            let mut row = bench::report::Row::new(series)
                .tag("task", task)
                .num("peak_intermediate_bytes", peaks[i] as f64);
            if let Some(stats) = c.metrics().last_run() {
                row = row.counters(stats);
            }
            rep.push(row);
        }
        println!(
            "{:<10} {:>14} {:>14} {:>14} {:>7.1}x",
            task,
            fmt_bytes(peaks[0]),
            fmt_bytes(peaks[1]),
            fmt_bytes(peaks[2]),
            peaks[2] as f64 / peaks[0].max(1) as f64
        );
    }
    println!("\nratio = conventional / blaze (paper: ~10x on keyed tasks, ~1x on knn)");

    match rep.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write bench json: {e}"),
    }
}
