//! Figure 10: cognitive load — distinct parallel APIs per task.
//!
//! Paper: Blaze needs the MapReduce function plus <5 utilities; Spark's
//! official implementations use ~30 distinct parallel primitives. The Blaze
//! side is counted *from our actual app sources* (static analysis of the
//! files in `rust/src/apps/`); the Spark side is the primitive inventory of
//! the referenced Spark 2.4 implementations. Datapoints (per-task API
//! counts) append to `BENCH_fig10_cognitive.json` via [`bench::report`].

use blaze::bench;
use blaze::util::cognitive::{
    blaze_apis_used, spark_distinct_for, spark_distinct_total, BLAZE_API, SPARK_PRIMITIVES,
};

const APP_SOURCES: &[(&str, &str)] = &[
    ("wordcount", include_str!("../rust/src/apps/wordcount.rs")),
    ("pagerank", include_str!("../rust/src/apps/pagerank.rs")),
    ("kmeans", include_str!("../rust/src/apps/kmeans.rs")),
    ("gmm", include_str!("../rust/src/apps/gmm.rs")),
    ("knn", include_str!("../rust/src/apps/knn.rs")),
];

fn main() {
    bench::figure_header(
        "Figure 10: Cognitive load (distinct parallel APIs used)",
        "Blaze: mapreduce + <5 utilities. Spark: ~30 distinct primitives",
    );
    println!(
        "{:<10} {:>12} {:>12}   blaze APIs used",
        "task", "blaze", "spark"
    );
    let mut rep = bench::report::Report::new("fig10_cognitive");
    let mut blaze_union: std::collections::BTreeSet<&str> = std::collections::BTreeSet::new();
    for (task, source) in APP_SOURCES {
        let used = blaze_apis_used(source);
        blaze_union.extend(used.iter());
        rep.push(
            bench::report::Row::new("api-count")
                .tag("task", task)
                .tag("blaze_apis", used.join(","))
                .num("blaze_distinct", used.len() as f64)
                .num("spark_distinct", spark_distinct_for(task) as f64),
        );
        println!(
            "{:<10} {:>12} {:>12}   {}",
            task,
            used.len(),
            spark_distinct_for(task),
            used.join(", ")
        );
    }
    let spark_total: usize = SPARK_PRIMITIVES.iter().map(|(_, p)| p.len()).sum();
    rep.meta("blaze_union_distinct", blaze_union.len());
    rep.meta("blaze_api_surface", BLAZE_API.len());
    rep.meta("spark_distinct_total", spark_distinct_total());
    println!(
        "\ntotals: Blaze {} distinct APIs (surface {} exported) vs Spark {} distinct ({} with repeats)",
        blaze_union.len(),
        BLAZE_API.len(),
        spark_distinct_total(),
        spark_total
    );
    println!("paper: Blaze = mapreduce + 3-5 utilities, Spark ~= 30 primitives");
    assert!(blaze_union.len() <= 7, "Blaze API surface grew past the paper's claim");

    match rep.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write bench json: {e}"),
    }
}
