//! Figure 8: Nearest-100-neighbors — total points processed per second.
//!
//! Paper: 200M random points; Blaze and Spark are *closest* on this task
//! (no intermediate key/value pairs — it's a distance scan + distributed
//! top-k). Expect the smallest speedup of the five workloads.

use blaze::apps::knn::knn;
use blaze::bench;
use blaze::coordinator::cluster::{Cluster, ClusterConfig, EngineKind};
use blaze::data::PointSet;
use blaze::runtime::Runtime;
use blaze::util::alloc::AllocMode;

fn main() {
    bench::figure_header(
        "Figure 8: Nearest 100 Neighbors (points/second)",
        "smallest Blaze-vs-Spark gap (no intermediate pairs); near-linear scaling",
    );
    let runtime = Runtime::load("artifacts").ok();
    let dim = runtime.as_ref().map_or(4, Runtime::dim);
    let scale = bench::scale();
    let ps = PointSet::uniform(120_000 * scale, dim, 44);
    let query = vec![0.5f32; dim];
    println!("{} points, dim={dim}, k=100, pjrt={}\n", ps.n, runtime.is_some());

    println!(
        "{:<6} {:>16} {:>16} {:>16} {:>9}",
        "nodes", "blaze (p/s)", "blaze-tcm", "conv (p/s)", "speedup"
    );
    for nodes in bench::node_sweep() {
        let run = |engine: EngineKind, alloc: AllocMode| {
            let c = Cluster::new(
                ClusterConfig::sized(nodes, 4).with_engine(engine).with_alloc(alloc),
            );
            knn(&c, &ps, &query, 100, runtime.as_ref()).0.throughput
        };
        let blaze = run(EngineKind::Eager, AllocMode::System);
        let tcm = run(EngineKind::Eager, AllocMode::Pool);
        let conv = run(EngineKind::Conventional, AllocMode::System);
        println!(
            "{:<6} {:>16.0} {:>16.0} {:>16.0} {:>8.1}x",
            nodes, blaze, tcm, conv, blaze / conv
        );
    }
}
