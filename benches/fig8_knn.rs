//! Figure 8: Nearest-100-neighbors — total points processed per second.
//!
//! Paper: 200M random points; Blaze and Spark are *closest* on this task
//! (no intermediate key/value pairs — it's a distance scan + distributed
//! top-k). Expect the smallest speedup of the five workloads. Datapoints
//! (throughput, run counters) append to `BENCH_fig8_knn.json` via
//! [`bench::report`].

use blaze::apps::knn::knn;
use blaze::bench;
use blaze::coordinator::cluster::{Cluster, ClusterConfig, EngineKind};
use blaze::data::PointSet;
use blaze::runtime::Runtime;
use blaze::util::alloc::AllocMode;

fn main() {
    bench::figure_header(
        "Figure 8: Nearest 100 Neighbors (points/second)",
        "smallest Blaze-vs-Spark gap (no intermediate pairs); near-linear scaling",
    );
    let runtime = Runtime::load("artifacts").ok();
    let dim = runtime.as_ref().map_or(4, Runtime::dim);
    let scale = bench::scale();
    let ps = PointSet::uniform(120_000 * scale, dim, 44);
    let query = vec![0.5f32; dim];
    println!("{} points, dim={dim}, k=100, pjrt={}\n", ps.n, runtime.is_some());

    let mut rep = bench::report::Report::new("fig8_knn");
    rep.meta("scale", scale);
    rep.meta("points", ps.n);
    rep.meta("pjrt", runtime.is_some());

    println!(
        "{:<6} {:>16} {:>16} {:>16} {:>9}",
        "nodes", "blaze (p/s)", "blaze-tcm", "conv (p/s)", "speedup"
    );
    for nodes in bench::node_sweep() {
        let run = |engine: EngineKind, alloc: AllocMode| {
            let c = Cluster::new(
                ClusterConfig::sized(nodes, 4).with_engine(engine).with_alloc(alloc),
            );
            let tput = knn(&c, &ps, &query, 100, runtime.as_ref()).0.throughput;
            let stats = c.metrics().last_run().cloned().expect("knn records runs");
            (tput, stats)
        };
        let (blaze, blaze_stats) = run(EngineKind::Eager, AllocMode::System);
        let (tcm, tcm_stats) = run(EngineKind::Eager, AllocMode::Pool);
        let (conv, conv_stats) = run(EngineKind::Conventional, AllocMode::System);
        for (series, tput, stats) in [
            ("blaze", blaze, &blaze_stats),
            ("blaze-tcm", tcm, &tcm_stats),
            ("conventional", conv, &conv_stats),
        ] {
            rep.push(
                bench::report::Row::new(series)
                    .tag("nodes", nodes)
                    .num("points_per_sec", tput)
                    .counters(stats),
            );
        }
        println!(
            "{:<6} {:>16.0} {:>16.0} {:>16.0} {:>8.1}x",
            nodes, blaze, tcm, conv, blaze / conv
        );
    }

    match rep.write() {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\ncould not write bench json: {e}"),
    }
}
