//! Figure 7: Expectation-Maximization (GMM) — points/second/iteration.
//!
//! Paper: 1M points, 5 components, 6 MapReduce operations per iteration;
//! Blaze >> Spark MLlib. The fused PJRT E-step carries the production
//! path; `benches/ablations.rs` compares it against the paper's literal
//! 6-MR decomposition. Datapoints (throughput, iterations, run counters)
//! append to `BENCH_fig7_gmm.json` via [`bench::report`].

use blaze::apps::gmm::gmm_from_points;
use blaze::bench;
use blaze::coordinator::cluster::{Cluster, ClusterConfig, EngineKind};
use blaze::data::PointSet;
use blaze::runtime::Runtime;
use blaze::util::alloc::AllocMode;

fn main() {
    bench::figure_header(
        "Figure 7: EM for Gaussian Mixture (points/second/iteration)",
        "Blaze >> Spark MLlib; 5 components; E-step on PJRT (Pallas logpdf kernel)",
    );
    let runtime = Runtime::load("artifacts").ok();
    let (dim, k) = runtime.as_ref().map_or((4, 5), |rt| (rt.dim(), rt.k()));
    let scale = bench::scale();
    let ps = PointSet::clustered(12_000 * scale, dim, k, 0.6, 43);
    println!("{} points, dim={dim}, k={k}, pjrt={}\n", ps.n, runtime.is_some());

    let mut rep = bench::report::Report::new("fig7_gmm");
    rep.meta("scale", scale);
    rep.meta("points", ps.n);
    rep.meta("pjrt", runtime.is_some());

    println!(
        "{:<6} {:>8} {:>16} {:>16} {:>16} {:>9}",
        "nodes", "iters", "blaze (p/s/it)", "blaze-tcm", "conv (p/s/it)", "speedup"
    );
    for nodes in bench::node_sweep() {
        let run = |engine: EngineKind, alloc: AllocMode| {
            let c = Cluster::new(
                ClusterConfig::sized(nodes, 4).with_engine(engine).with_alloc(alloc),
            );
            let (report, result) = gmm_from_points(&c, &ps, k, 1e-6, 15, runtime.as_ref());
            let stats = c.metrics().last_run().cloned().expect("gmm records runs");
            (report.throughput, result.iterations, stats)
        };
        let (blaze, iters, blaze_stats) = run(EngineKind::Eager, AllocMode::System);
        let (tcm, _, tcm_stats) = run(EngineKind::Eager, AllocMode::Pool);
        let (conv, _, conv_stats) = run(EngineKind::Conventional, AllocMode::System);
        for (series, tput, stats) in [
            ("blaze", blaze, &blaze_stats),
            ("blaze-tcm", tcm, &tcm_stats),
            ("conventional", conv, &conv_stats),
        ] {
            rep.push(
                bench::report::Row::new(series)
                    .tag("nodes", nodes)
                    .num("points_per_sec_per_iter", tput)
                    .num("iterations", iters as f64)
                    .counters(stats),
            );
        }
        println!(
            "{:<6} {:>8} {:>16.0} {:>16.0} {:>16.0} {:>8.1}x",
            nodes, iters, blaze, tcm, conv, blaze / conv
        );
    }

    match rep.write() {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\ncould not write bench json: {e}"),
    }
}
