//! Figure 7: Expectation-Maximization (GMM) — points/second/iteration.
//!
//! Paper: 1M points, 5 components, 6 MapReduce operations per iteration;
//! Blaze >> Spark MLlib. The fused PJRT E-step carries the production
//! path; `benches/ablations.rs` compares it against the paper's literal
//! 6-MR decomposition.

use blaze::apps::gmm::gmm_from_points;
use blaze::bench;
use blaze::coordinator::cluster::{Cluster, ClusterConfig, EngineKind};
use blaze::data::PointSet;
use blaze::runtime::Runtime;
use blaze::util::alloc::AllocMode;

fn main() {
    bench::figure_header(
        "Figure 7: EM for Gaussian Mixture (points/second/iteration)",
        "Blaze >> Spark MLlib; 5 components; E-step on PJRT (Pallas logpdf kernel)",
    );
    let runtime = Runtime::load("artifacts").ok();
    let (dim, k) = runtime.as_ref().map_or((4, 5), |rt| (rt.dim(), rt.k()));
    let scale = bench::scale();
    let ps = PointSet::clustered(12_000 * scale, dim, k, 0.6, 43);
    println!("{} points, dim={dim}, k={k}, pjrt={}\n", ps.n, runtime.is_some());

    println!(
        "{:<6} {:>8} {:>16} {:>16} {:>16} {:>9}",
        "nodes", "iters", "blaze (p/s/it)", "blaze-tcm", "conv (p/s/it)", "speedup"
    );
    for nodes in bench::node_sweep() {
        let run = |engine: EngineKind, alloc: AllocMode| {
            let c = Cluster::new(
                ClusterConfig::sized(nodes, 4).with_engine(engine).with_alloc(alloc),
            );
            let (report, result) = gmm_from_points(&c, &ps, k, 1e-6, 15, runtime.as_ref());
            (report.throughput, result.iterations)
        };
        let (blaze, iters) = run(EngineKind::Eager, AllocMode::System);
        let (tcm, _) = run(EngineKind::Eager, AllocMode::Pool);
        let (conv, _) = run(EngineKind::Conventional, AllocMode::System);
        println!(
            "{:<6} {:>8} {:>16.0} {:>16.0} {:>16.0} {:>8.1}x",
            nodes, iters, blaze, tcm, conv, blaze / conv
        );
    }
}
