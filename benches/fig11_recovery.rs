//! Figure 11 (new): recovery overhead under a mid-job worker failure.
//!
//! For wordcount and k-means, under both engines, compares the virtual
//! makespan of a checkpointed failure-free run against the same seeded run
//! with one injected node death, and reports the recovery overhead as a
//! fraction of the failure-free makespan. Wordcount additionally compares
//! the two recovery policies — hot-standby restore vs `--evacuate` slot
//! re-homing (k-means reduces into a driver-resident `Vec`, which cannot
//! re-home keys). Results are asserted identical between all runs —
//! recovery may cost time, never correctness. Datapoints (makespans,
//! overhead, fault counters) append to `BENCH_fig11_recovery.json` via
//! [`bench::report`].

use blaze::apps::{kmeans, wordcount::wordcount};
use blaze::bench;
use blaze::coordinator::cluster::{Cluster, ClusterConfig, EngineKind};
use blaze::data::PointSet;
use blaze::prelude::*;

const NODES: usize = 4;
const WORKERS: usize = 4;
const CKPT_EVERY: usize = 4;

fn cluster(engine: EngineKind, plan: FailurePlan, evacuate: bool) -> Cluster {
    Cluster::new(ClusterConfig::sized(NODES, WORKERS).with_engine(engine).with_fault(
        FaultConfig::default()
            .with_checkpoint_every(CKPT_EVERY)
            .with_plan(plan)
            .with_evacuation(evacuate),
    ))
}

/// Kill node 2 midway through the job's `NODES * WORKERS` map blocks.
/// Deliberately misaligned with `CKPT_EVERY` (a kill at a checkpoint
/// boundary finds a fresh snapshot and rolls back nothing) so the
/// measured overhead includes rollback + block replay, not just restore
/// traffic and reassignment.
fn midjob_failure() -> FailurePlan {
    let block = NODES * WORKERS / 2 - 2;
    assert!(block % CKPT_EVERY != 0, "kill block must not sit on a checkpoint");
    FailurePlan::kill_at_block(2, block)
}

fn main() {
    bench::figure_header(
        "Figure 11: Recovery overhead (failure vs failure-free makespan)",
        "identical results; recovery cost = re-executed blocks + restore traffic",
    );
    let scale = bench::scale();

    let mut rep = bench::report::Report::new("fig11_recovery");
    rep.meta("scale", scale);
    rep.meta("checkpoint_every", CKPT_EVERY);

    println!(
        "{:<10} {:<13} {:<12} {:>14} {:>14} {:>10}",
        "task", "engine", "policy", "no-fail (s)", "failure (s)", "overhead"
    );

    // ---- Wordcount (both recovery policies) ------------------------------
    let lines = blaze::data::corpus_lines(20_000 * scale, 10, 42);
    for engine in [EngineKind::Eager, EngineKind::Conventional] {
        let run = |plan: FailurePlan, evacuate: bool| {
            let c = cluster(engine, plan, evacuate);
            let dv = DistVector::from_vec(&c, lines.clone());
            let (report, words) = wordcount(&c, &dv);
            let stats = c
                .metrics()
                .runs()
                .iter()
                .find(|r| r.label == "wordcount.mr")
                .cloned()
                .expect("wordcount records wordcount.mr");
            (report.makespan_sec, words.collect(), stats)
        };
        let (base_s, base_counts, _) = run(FailurePlan::none(), false);
        for (policy, evacuate) in [("hot-standby", false), ("evacuate", true)] {
            let (fail_s, fail_counts, stats) = run(midjob_failure(), evacuate);
            assert_eq!(base_counts, fail_counts, "wordcount counts must survive failure");
            assert_eq!(
                evacuate,
                stats.evac_bytes > 0,
                "evacuation traffic must be charged iff the policy is on"
            );
            rep.push(
                bench::report::Row::new("wordcount")
                    .tag("engine", engine)
                    .tag("policy", policy)
                    .num("nofail_makespan_sec", base_s)
                    .num("failure_makespan_sec", fail_s)
                    .num("overhead_frac", fail_s / base_s - 1.0)
                    .counters(&stats),
            );
            println!(
                "{:<10} {:<13} {:<12} {:>14.4} {:>14.4} {:>9.1}%",
                "wordcount",
                engine,
                policy,
                base_s,
                fail_s,
                (fail_s / base_s - 1.0) * 100.0
            );
        }
    }

    // ---- K-means (driver-resident target: hot-standby only) --------------
    let ps = PointSet::clustered(20_000 * scale, 4, 5, 0.6, 42);
    let init = kmeans::init_first_k(&ps, 5);
    for engine in [EngineKind::Eager, EngineKind::Conventional] {
        let run = |plan: FailurePlan| {
            let c = cluster(engine, plan, false);
            let blocks = kmeans::distribute_blocks(&c, &ps, 512);
            let (report, result) =
                kmeans::kmeans(&c, &blocks, ps.n, 4, 5, init.clone(), 1e-4, 10, None);
            let stats = c.metrics().last_run().cloned().expect("kmeans records runs");
            (report.makespan_sec, result.centers, stats)
        };
        let (base_s, base_centers, _) = run(FailurePlan::none());
        let (fail_s, fail_centers, fail_stats) = run(midjob_failure());
        assert_eq!(base_centers, fail_centers, "centroids must be byte-identical");
        rep.push(
            bench::report::Row::new("kmeans")
                .tag("engine", engine)
                .tag("policy", "hot-standby")
                .num("nofail_makespan_sec", base_s)
                .num("failure_makespan_sec", fail_s)
                .num("overhead_frac", fail_s / base_s - 1.0)
                .counters(&fail_stats),
        );
        println!(
            "{:<10} {:<13} {:<12} {:>14.4} {:>14.4} {:>9.1}%",
            "kmeans",
            engine,
            "hot-standby",
            base_s,
            fail_s,
            (fail_s / base_s - 1.0) * 100.0
        );
    }

    println!("\nresults byte-identical across failure, failure-free, and policy runs");

    match rep.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write bench json: {e}"),
    }
}
