//! Figure 11 (new): recovery overhead under a mid-job worker failure.
//!
//! For wordcount and k-means, under both engines, compares the virtual
//! makespan of a checkpointed failure-free run against the same seeded run
//! with one injected node death, and reports the recovery overhead as a
//! fraction of the failure-free makespan. Results are asserted identical
//! between the two runs — recovery may cost time, never correctness.

use blaze::apps::{kmeans, wordcount::wordcount};
use blaze::bench;
use blaze::coordinator::cluster::{Cluster, ClusterConfig, EngineKind};
use blaze::data::PointSet;
use blaze::prelude::*;

const NODES: usize = 4;
const WORKERS: usize = 4;
const CKPT_EVERY: usize = 4;

fn cluster(engine: EngineKind, plan: FailurePlan) -> Cluster {
    Cluster::new(ClusterConfig::sized(NODES, WORKERS).with_engine(engine).with_fault(
        FaultConfig::default().with_checkpoint_every(CKPT_EVERY).with_plan(plan),
    ))
}

/// Kill node 2 midway through the job's `NODES * WORKERS` map blocks.
/// Deliberately misaligned with `CKPT_EVERY` (a kill at a checkpoint
/// boundary finds a fresh snapshot and rolls back nothing) so the
/// measured overhead includes rollback + block replay, not just restore
/// traffic and reassignment.
fn midjob_failure() -> FailurePlan {
    let block = NODES * WORKERS / 2 - 2;
    assert!(block % CKPT_EVERY != 0, "kill block must not sit on a checkpoint");
    FailurePlan::kill_at_block(2, block)
}

fn main() {
    bench::figure_header(
        "Figure 11: Recovery overhead (failure vs failure-free makespan)",
        "identical results; recovery cost = re-executed blocks + restore traffic",
    );
    let scale = bench::scale();

    println!(
        "{:<10} {:<13} {:>14} {:>14} {:>10}",
        "task", "engine", "no-fail (s)", "failure (s)", "overhead"
    );

    // ---- Wordcount ------------------------------------------------------
    let lines = blaze::data::corpus_lines(20_000 * scale, 10, 42);
    for engine in [EngineKind::Eager, EngineKind::Conventional] {
        let run = |plan: FailurePlan| {
            let c = cluster(engine, plan);
            let dv = DistVector::from_vec(&c, lines.clone());
            let (report, words) = wordcount(&c, &dv);
            (report.makespan_sec, words.collect())
        };
        let (base_s, base_counts) = run(FailurePlan::none());
        let (fail_s, fail_counts) = run(midjob_failure());
        assert_eq!(base_counts, fail_counts, "wordcount counts must survive failure");
        println!(
            "{:<10} {:<13} {:>14.4} {:>14.4} {:>9.1}%",
            "wordcount",
            engine,
            base_s,
            fail_s,
            (fail_s / base_s - 1.0) * 100.0
        );
    }

    // ---- K-means --------------------------------------------------------
    let ps = PointSet::clustered(20_000 * scale, 4, 5, 0.6, 42);
    let init = kmeans::init_first_k(&ps, 5);
    for engine in [EngineKind::Eager, EngineKind::Conventional] {
        let run = |plan: FailurePlan| {
            let c = cluster(engine, plan);
            let blocks = kmeans::distribute_blocks(&c, &ps, 512);
            let (report, result) =
                kmeans::kmeans(&c, &blocks, ps.n, 4, 5, init.clone(), 1e-4, 10, None);
            (report.makespan_sec, result.centers)
        };
        let (base_s, base_centers) = run(FailurePlan::none());
        let (fail_s, fail_centers) = run(midjob_failure());
        assert_eq!(base_centers, fail_centers, "centroids must be byte-identical");
        println!(
            "{:<10} {:<13} {:>14.4} {:>14.4} {:>9.1}%",
            "kmeans",
            engine,
            base_s,
            fail_s,
            (fail_s / base_s - 1.0) * 100.0
        );
    }

    println!("\nresults byte-identical across failure and failure-free runs");
}
