//! Figure 11 (new): recovery overhead under a mid-job worker failure.
//!
//! For wordcount and k-means, under both engines, compares the virtual
//! makespan of a checkpointed failure-free run against the same seeded run
//! with one injected node death, and reports the recovery overhead as a
//! fraction of the failure-free makespan. Wordcount additionally compares
//! the two recovery policies — hot-standby restore vs `--evacuate` slot
//! re-homing (k-means reduces into a driver-resident `Vec`, which cannot
//! re-home keys). Results are asserted identical between all runs —
//! recovery may cost time, never correctness. Datapoints (makespans,
//! overhead, fault counters) append to `BENCH_fig11_recovery.json` via
//! [`bench::report`].

use blaze::apps::{kmeans, wordcount::wordcount};
use blaze::bench;
use blaze::coordinator::cluster::{Backend, Cluster, ClusterConfig, EngineKind};
use blaze::data::PointSet;
use blaze::exec::transport::TransportFaultPlan;
use blaze::prelude::*;

const NODES: usize = 4;
const WORKERS: usize = 4;
const CKPT_EVERY: usize = 4;

fn cluster(engine: EngineKind, plan: FailurePlan, evacuate: bool) -> Cluster {
    Cluster::new(ClusterConfig::sized(NODES, WORKERS).with_engine(engine).with_fault(
        FaultConfig::default()
            .with_checkpoint_every(CKPT_EVERY)
            .with_plan(plan)
            .with_evacuation(evacuate),
    ))
}

/// Kill node 2 midway through the job's `NODES * WORKERS` map blocks.
/// Deliberately misaligned with `CKPT_EVERY` (a kill at a checkpoint
/// boundary finds a fresh snapshot and rolls back nothing) so the
/// measured overhead includes rollback + block replay, not just restore
/// traffic and reassignment.
fn midjob_failure() -> FailurePlan {
    let block = NODES * WORKERS / 2 - 2;
    assert!(block % CKPT_EVERY != 0, "kill block must not sit on a checkpoint");
    FailurePlan::kill_at_block(2, block)
}

/// Kill node 2 *inside* a block's map — sub-task granularity. Blocks
/// `2*WORKERS .. 3*WORKERS` are homed on node 2; pick one misaligned
/// with `CKPT_EVERY` (same reasoning as [`midjob_failure`]) so the
/// overhead includes the charged-but-discarded partial map on top of
/// rollback + replay.
fn midblock_failure() -> FailurePlan {
    let block = 2 * WORKERS + 1;
    assert!(block % CKPT_EVERY != 0, "kill block must not sit on a checkpoint");
    FailurePlan::kill_at_item(2, block, 200)
}

fn main() {
    bench::figure_header(
        "Figure 11: Recovery overhead (failure vs failure-free makespan)",
        "identical results; recovery cost = re-executed blocks + restore traffic",
    );
    let scale = bench::scale();

    let mut rep = bench::report::Report::new("fig11_recovery");
    rep.meta("scale", scale);
    rep.meta("checkpoint_every", CKPT_EVERY);

    println!(
        "{:<10} {:<13} {:<12} {:>14} {:>14} {:>10}",
        "task", "engine", "policy", "no-fail (s)", "failure (s)", "overhead"
    );

    // ---- Wordcount (both recovery policies) ------------------------------
    let lines = blaze::data::corpus_lines(20_000 * scale, 10, 42);
    for engine in [EngineKind::Eager, EngineKind::Conventional] {
        let run = |plan: FailurePlan, evacuate: bool| {
            let c = cluster(engine, plan, evacuate);
            let dv = DistVector::from_vec(&c, lines.clone());
            let (report, words) = wordcount(&c, &dv);
            let stats = c
                .metrics()
                .runs()
                .iter()
                .find(|r| r.label == "wordcount.mr")
                .cloned()
                .expect("wordcount records wordcount.mr");
            let aborts: u64 = c
                .metrics()
                .runs()
                .iter()
                .filter_map(|r| r.counter("fault.midblock_aborts"))
                .sum();
            (report.makespan_sec, words.collect(), stats, aborts)
        };
        let (base_s, base_counts, _, _) = run(FailurePlan::none(), false);
        for (policy, evacuate) in [("hot-standby", false), ("evacuate", true)] {
            let (fail_s, fail_counts, stats, _) = run(midjob_failure(), evacuate);
            assert_eq!(base_counts, fail_counts, "wordcount counts must survive failure");
            assert_eq!(
                evacuate,
                stats.evac_bytes > 0,
                "evacuation traffic must be charged iff the policy is on"
            );
            rep.push(
                bench::report::Row::new("wordcount")
                    .tag("engine", engine)
                    .tag("policy", policy)
                    .num("nofail_makespan_sec", base_s)
                    .num("failure_makespan_sec", fail_s)
                    .num("overhead_frac", fail_s / base_s - 1.0)
                    .counters(&stats),
            );
            println!(
                "{:<10} {:<13} {:<12} {:>14.4} {:>14.4} {:>9.1}%",
                "wordcount",
                engine,
                policy,
                base_s,
                fail_s,
                (fail_s / base_s - 1.0) * 100.0
            );
        }

        // Mid-block: the kill lands after 200 items of one block's map —
        // not at a commit boundary — so the overhead also pays for the
        // charged-but-discarded partial attempt.
        let (fail_s, fail_counts, stats, aborts) = run(midblock_failure(), false);
        assert_eq!(base_counts, fail_counts, "wordcount counts must survive a mid-block kill");
        assert!(aborts > 0, "mid-block kill must abort an in-flight map");
        rep.push(
            bench::report::Row::new("wordcount")
                .tag("engine", engine)
                .tag("policy", "mid-block")
                .num("nofail_makespan_sec", base_s)
                .num("failure_makespan_sec", fail_s)
                .num("overhead_frac", fail_s / base_s - 1.0)
                .num("midblock_aborts", aborts as f64)
                .counters(&stats),
        );
        println!(
            "{:<10} {:<13} {:<12} {:>14.4} {:>14.4} {:>9.1}%",
            "wordcount",
            engine,
            "mid-block",
            base_s,
            fail_s,
            (fail_s / base_s - 1.0) * 100.0
        );
    }

    // ---- Wordcount over a lossy transport (eager engine, threaded) -------
    // The conventional engine is never threaded and the fault engine's
    // shuffle is flow-model only, so the lossy channel path belongs to the
    // ordinary eager engine under `Backend::Threaded`. The deterministic
    // virtual-time mirror charges every retry's backoff, so the overhead
    // column is the reliability cost of the lossy network; the
    // `transport.*` counters ride along in each row.
    {
        let run_threaded = |net: Option<TransportFaultPlan>| {
            let mut cfg = ClusterConfig::sized(NODES, WORKERS)
                .with_engine(EngineKind::Eager)
                .with_backend(Backend::Threaded(2));
            if let Some(plan) = net {
                cfg = cfg.with_net_fault(plan);
            }
            let c = Cluster::new(cfg);
            let dv = DistVector::from_vec(&c, lines.clone());
            let (report, words) = wordcount(&c, &dv);
            let stats = c
                .metrics()
                .runs()
                .iter()
                .find(|r| r.label == "wordcount.mr")
                .cloned()
                .expect("wordcount records wordcount.mr");
            (report.makespan_sec, words.collect(), stats)
        };
        let (base_s, base_counts, base_stats) = run_threaded(None);
        assert!(
            base_stats.counter("transport.retries").is_none(),
            "a lossless run must keep its counter set unchanged"
        );

        // Aggressive loss so retries are observed at any seed; unbounded
        // retry budget so delivery still succeeds.
        let lossy = TransportFaultPlan::new(0.5, 0.1, 0xF16_11AA)
            .with_retry_max(64)
            .with_timeout_ns(u64::MAX);
        let (lossy_s, lossy_counts, stats) = run_threaded(Some(lossy));
        assert_eq!(base_counts, lossy_counts, "wordcount counts must survive a lossy transport");
        assert!(
            stats.counter("transport.retries").unwrap_or(0) > 0,
            "a lossy plan at these rates must observe retries"
        );
        rep.push(
            bench::report::Row::new("wordcount-lossy")
                .tag("engine", EngineKind::Eager)
                .tag("policy", "retry-backoff")
                .num("nofail_makespan_sec", base_s)
                .num("failure_makespan_sec", lossy_s)
                .num("overhead_frac", lossy_s / base_s - 1.0)
                .counters(&stats),
        );
        println!(
            "{:<10} {:<13} {:<12} {:>14.4} {:>14.4} {:>9.1}%",
            "wc-lossy",
            EngineKind::Eager,
            "retry-backoff",
            base_s,
            lossy_s,
            (lossy_s / base_s - 1.0) * 100.0
        );

        // Total loss: every frame exhausts its retry budget and the run
        // degrades to the flow-model shuffle — a structured timeout, never
        // a hang, and still byte-identical results.
        let dead = TransportFaultPlan::new(1.0, 0.0, 0xF16_11AB).with_retry_max(3);
        let (dead_s, dead_counts, stats) = run_threaded(Some(dead));
        assert_eq!(base_counts, dead_counts, "timeout fallback must preserve results");
        assert!(
            stats.counter("transport.timeouts").unwrap_or(0) > 0,
            "a dead link must be reported as a timeout"
        );
        rep.push(
            bench::report::Row::new("wordcount-lossy")
                .tag("engine", EngineKind::Eager)
                .tag("policy", "timeout-fallback")
                .num("nofail_makespan_sec", base_s)
                .num("failure_makespan_sec", dead_s)
                .num("overhead_frac", dead_s / base_s - 1.0)
                .counters(&stats),
        );
        println!(
            "{:<10} {:<13} {:<12} {:>14.4} {:>14.4} {:>9.1}%",
            "wc-lossy",
            EngineKind::Eager,
            "timeout-fb",
            base_s,
            dead_s,
            (dead_s / base_s - 1.0) * 100.0
        );
    }

    // ---- K-means (driver-resident target: hot-standby only) --------------
    let ps = PointSet::clustered(20_000 * scale, 4, 5, 0.6, 42);
    let init = kmeans::init_first_k(&ps, 5);
    for engine in [EngineKind::Eager, EngineKind::Conventional] {
        let run = |plan: FailurePlan| {
            let c = cluster(engine, plan, false);
            let blocks = kmeans::distribute_blocks(&c, &ps, 512);
            let (report, result) =
                kmeans::kmeans(&c, &blocks, ps.n, 4, 5, init.clone(), 1e-4, 10, None);
            let stats = c.metrics().last_run().cloned().expect("kmeans records runs");
            (report.makespan_sec, result.centers, stats)
        };
        let (base_s, base_centers, _) = run(FailurePlan::none());
        let (fail_s, fail_centers, fail_stats) = run(midjob_failure());
        assert_eq!(base_centers, fail_centers, "centroids must be byte-identical");
        rep.push(
            bench::report::Row::new("kmeans")
                .tag("engine", engine)
                .tag("policy", "hot-standby")
                .num("nofail_makespan_sec", base_s)
                .num("failure_makespan_sec", fail_s)
                .num("overhead_frac", fail_s / base_s - 1.0)
                .counters(&fail_stats),
        );
        println!(
            "{:<10} {:<13} {:<12} {:>14.4} {:>14.4} {:>9.1}%",
            "kmeans",
            engine,
            "hot-standby",
            base_s,
            fail_s,
            (fail_s / base_s - 1.0) * 100.0
        );
    }

    println!("\nresults byte-identical across failure, failure-free, and policy runs");

    match rep.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write bench json: {e}"),
    }
}
