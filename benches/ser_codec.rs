//! §2.3.2 serialization ablation: fast (tag-less) codec vs the
//! protobuf-style tagged codec.
//!
//! Paper: a (small int, small int) pair is 2 bytes under fast serialization
//! vs 4 bytes under Protocol Buffers — 50% smaller — and tag processing
//! costs CPU on both ends. This bench reports message sizes and
//! encode/decode throughput for the three payload shapes the workloads
//! actually shuffle.

use blaze::bench::{self, fmt_bytes};
use blaze::ser::fastser::{decode_pairs, encode_pairs};
use blaze::ser::tagged::{decode_pairs_tagged, encode_pairs_tagged};
use blaze::util::rng::SplitRng;

fn bench_shape<K, V>(name: &str, pairs: &[(K, V)])
where
    K: blaze::ser::FastSer + blaze::ser::TaggedSer + Clone + PartialEq + std::fmt::Debug,
    V: blaze::ser::FastSer + blaze::ser::TaggedSer + Clone + PartialEq + std::fmt::Debug,
{
    let reps = bench::reps().max(5);
    let fast_buf = encode_pairs(pairs);
    let tagged_buf = encode_pairs_tagged(pairs);
    assert_eq!(&decode_pairs::<K, V>(&fast_buf).unwrap(), pairs);
    assert_eq!(&decode_pairs_tagged::<K, V>(&tagged_buf).unwrap(), pairs);

    let enc_fast = bench::time_host(reps, || encode_pairs(pairs));
    let enc_tag = bench::time_host(reps, || encode_pairs_tagged(pairs));
    let dec_fast = bench::time_host(reps, || decode_pairs::<K, V>(&fast_buf).unwrap());
    let dec_tag = bench::time_host(reps, || decode_pairs_tagged::<K, V>(&tagged_buf).unwrap());

    let n = pairs.len() as f64;
    println!("--- {name} ({} pairs) ---", pairs.len());
    println!(
        "  size:   fast {:>12}  tagged {:>12}  ratio {:.2}x",
        fmt_bytes(fast_buf.len() as u64),
        fmt_bytes(tagged_buf.len() as u64),
        tagged_buf.len() as f64 / fast_buf.len() as f64
    );
    println!(
        "  encode: fast {:>10.1} Mpairs/s  tagged {:>10.1} Mpairs/s  speedup {:.2}x",
        n / enc_fast.mean / 1e6,
        n / enc_tag.mean / 1e6,
        enc_tag.mean / enc_fast.mean
    );
    println!(
        "  decode: fast {:>10.1} Mpairs/s  tagged {:>10.1} Mpairs/s  speedup {:.2}x",
        n / dec_fast.mean / 1e6,
        n / dec_tag.mean / 1e6,
        dec_tag.mean / dec_fast.mean
    );
}

fn main() {
    bench::figure_header(
        "Serialization ablation (paper 2.3.2)",
        "fast codec = 2 B/small-int pair vs protobuf-style 4 B (50% smaller)",
    );
    let n = 200_000 * bench::scale();
    let mut rng = SplitRng::new(7, 0);

    // Shape 1: the paper's headline — small-int key/value (pi, histogram).
    let small: Vec<(u64, u64)> = (0..n).map(|_| (rng.below(5), 1u64)).collect();
    // Paper's exact size claim on a single pair:
    use blaze::ser::fastser::FastSer;
    use blaze::ser::tagged::TaggedSer;
    let pair = (0u64, 1u64);
    println!(
        "single (0,1) pair: fast {} B, tagged {} B (paper: 2 vs 4)\n",
        pair.encoded_len(),
        pair.tagged_len()
    );
    bench_shape("small ints (word counts, histograms)", &small);

    // Shape 2: word count — short string keys, small counts.
    let words: Vec<(String, u64)> = (0..n / 4)
        .map(|_| {
            let len = 3 + rng.below(8) as usize;
            let s: String =
                (0..len).map(|_| char::from(b'a' + rng.below(26) as u8)).collect();
            (s, 1 + rng.below(100))
        })
        .collect();
    bench_shape("string keys (word count)", &words);

    // Shape 3: pagerank contributions — int key, f64 value.
    let ranks: Vec<(u32, f64)> = (0..n / 2)
        .map(|_| (rng.below(1 << 20) as u32, rng.uniform()))
        .collect();
    bench_shape("u32 -> f64 (pagerank contributions)", &ranks);
}
