//! Quickstart: word frequency count, the paper's Appendix A.1 example.
//!
//! ```text
//! cargo run --release --example quickstart [path/to/text.txt]
//! ```
//!
//! With no argument a synthetic Zipf corpus is generated. This is the whole
//! Blaze API in one screen: a cluster, a distributed container, one
//! `mapreduce` call, and `collect`.

use blaze::prelude::*;

fn main() {
    let cluster = Cluster::local(4, 4); // 4 virtual nodes x 4 workers

    // Load file into a distributed container (paper's `load_file`) or
    // generate a corpus.
    let lines: DistVector<String> = match std::env::args().nth(1) {
        Some(path) => load_file(&cluster, &path).expect("readable text file"),
        None => DistVector::from_vec(&cluster, blaze::data::corpus_lines(20_000, 10, 42)),
    };

    // Define target hash map.
    let mut words: DistHashMap<String, u64> = DistHashMap::new(&cluster);

    // Perform mapreduce: split each line, emit (word, 1), reduce with sum.
    mapreduce(
        &lines,
        |_, line: &String, emit| {
            for w in line.split_whitespace() {
                emit(w.to_string(), 1u64);
            }
        },
        "sum",
        &mut words,
    );

    // Output number of unique words (paper's `words.size()`).
    println!("unique words: {}", words.len());

    // Top 10 by count, via the distributed vector's topk.
    let counts: Vec<(u64, String)> = collect_hashmap(&words)
        .into_iter()
        .map(|(w, c)| (c, w))
        .collect();
    let dv = DistVector::from_vec(&cluster, counts);
    for (c, w) in dv.topk(10, |a, b| a.0.cmp(&b.0)) {
        println!("{w:>12}  {c}");
    }

    let m = cluster.metrics();
    let run = m.runs().first().expect("run recorded");
    println!(
        "\n{} pairs emitted, {} shuffled ({}x combine), {} B cross-node, virtual makespan {:.4}s",
        run.pairs_emitted,
        run.pairs_shuffled,
        run.pairs_emitted / run.pairs_shuffled.max(1),
        run.shuffle_bytes,
        run.makespan_sec
    );
}
