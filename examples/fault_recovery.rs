//! Fault recovery demo: kill a worker mid-wordcount and finish anyway.
//!
//! ```text
//! cargo run --release --example fault_recovery
//! ```
//!
//! Runs the same seeded wordcount four ways — ordinary eager engine,
//! recoverable engine without failures, recoverable engine with node 2
//! dying mid-job (hot-standby restore), and the same death recovered with
//! `--evacuate`-style slot re-homing — and shows that all four produce
//! identical counts while the failure runs pay a visible recovery overhead
//! in the virtual makespan.

use blaze::apps::wordcount::wordcount;
use blaze::prelude::*;

fn main() {
    let lines = blaze::data::corpus_lines(20_000, 10, 42);

    let run = |fault: FaultConfig| {
        let cluster = Cluster::new(ClusterConfig::sized(4, 2).with_fault(fault));
        let dv = DistVector::from_vec(&cluster, lines.clone());
        let (report, words) = wordcount(&cluster, &dv);
        let notes: Vec<String> = cluster.metrics().notes().to_vec();
        (report, words.collect(), notes)
    };

    let (base, counts_base, _) = run(FaultConfig::disabled());
    let (ckpt, counts_ckpt, _) = run(FaultConfig::default().with_checkpoint_every(4));
    let (fail, counts_fail, notes) = run(FaultConfig::default()
        .with_checkpoint_every(4)
        .with_plan(FailurePlan::kill_at_block(2, 3)));
    let (evac, counts_evac, evac_notes) = run(FaultConfig::default()
        .with_checkpoint_every(4)
        .with_plan(FailurePlan::kill_at_block(2, 3))
        .with_evacuation(true));

    println!("corpus: {} lines", lines.len());
    println!("plain eager     : makespan {:>9.4}s  unique {}", base.makespan_sec, counts_base.len());
    println!("ckpt, no failure: makespan {:>9.4}s  unique {}", ckpt.makespan_sec, counts_ckpt.len());
    println!("ckpt + failure  : makespan {:>9.4}s  unique {}", fail.makespan_sec, counts_fail.len());
    println!("  (hot-standby restore: routing unchanged)");
    for note in notes.iter().filter(|n| n.starts_with("fault[")) {
        println!("  {note}");
    }
    println!("ckpt + evacuate : makespan {:>9.4}s  unique {}", evac.makespan_sec, counts_evac.len());
    println!("  (dead node's keys re-homed onto survivors, migration charged)");
    for note in evac_notes.iter().filter(|n| n.starts_with("fault[")) {
        println!("  {note}");
    }

    // u64 counts are exact under any reduce order, so the recoverable
    // engine must agree with the plain eager engine bit-for-bit — under
    // either recovery policy.
    assert_eq!(counts_base, counts_ckpt, "checkpointing must not change results");
    assert_eq!(counts_base, counts_fail, "recovery must reproduce results exactly");
    assert_eq!(counts_base, counts_evac, "evacuation must reproduce results exactly");
    let overhead = fail.makespan_sec / ckpt.makespan_sec - 1.0;
    let evac_overhead = evac.makespan_sec / ckpt.makespan_sec - 1.0;
    println!(
        "recovery overhead vs failure-free checkpointed run: hot-standby {:.1}%, evacuate {:.1}%",
        overhead * 100.0,
        evac_overhead * 100.0
    );
    println!("all four runs produced byte-identical counts");
}
