//! End-to-end validation driver (DESIGN.md §Validation story).
//!
//! ```text
//! cargo run --release --example pipeline_e2e [--scale S]
//! ```
//!
//! Runs **all five** paper workloads (word count, PageRank, k-means,
//! EM-GMM, 100-NN) plus Monte-Carlo π on the simulated cluster at 1/2/4/8
//! nodes under **both** engines, with the PJRT artifacts on the k-means /
//! GMM / k-NN hot paths when available. Prints the paper's headline
//! metric — per-task throughput and the Blaze-vs-conventional speedup —
//! in EXPERIMENTS.md-ready rows. The paper's claim is >10x average.

use blaze::apps::{gmm, kmeans, knn, pagerank, pi, wordcount, TaskReport};
use blaze::coordinator::cluster::{Cluster, ClusterConfig, EngineKind};
use blaze::data::{corpus_lines, Graph, PointSet};
use blaze::prelude::*;
use blaze::runtime::Runtime;

fn cluster(nodes: usize, engine: EngineKind) -> Cluster {
    Cluster::new(ClusterConfig::sized(nodes, 4).with_engine(engine))
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let scale: usize = args
        .iter()
        .position(|a| a == "--scale")
        .and_then(|i| args.get(i + 1))
        .map_or(1, |s| s.parse().expect("scale"));

    let runtime = Runtime::load("artifacts").ok();
    match &runtime {
        Some(rt) => println!("PJRT runtime loaded: {rt:?}"),
        None => println!("no artifacts; scalar mappers (run `make artifacts` for the full stack)"),
    }
    let (dim, k) = runtime.as_ref().map_or((4, 5), |rt| (rt.dim(), rt.k()));
    let batch = runtime.as_ref().map_or(4096, Runtime::batch);

    // Workload data (fixed across engines and cluster shapes).
    let lines = corpus_lines(40_000 * scale, 10, 42);
    let n_words: u64 = lines.iter().map(|l| l.split_whitespace().count() as u64).sum();
    let graph = Graph::graph500(15 + scale.ilog2(), 16, 42);
    let km_points = PointSet::clustered(240_000 * scale, dim, k, 0.6, 42);
    let gmm_points = PointSet::clustered(48_000 * scale, dim, k, 0.6, 43);
    let knn_points = PointSet::uniform(120_000 * scale, dim, 44);
    let query = vec![0.5f32; dim];
    println!(
        "workloads: {} words | {} links | {}/{}/{} points (kmeans/gmm/knn)\n",
        n_words,
        graph.n_edges(),
        km_points.n,
        gmm_points.n,
        knn_points.n
    );

    let node_counts = [1usize, 2, 4, 8];
    let engines = [EngineKind::Eager, EngineKind::Conventional];
    let mut rows: Vec<TaskReport> = Vec::new();

    for &nodes in &node_counts {
        for &engine in &engines {
            // --- word count ---
            let c = cluster(nodes, engine);
            let dv = DistVector::from_vec(&c, lines.clone());
            rows.push(wordcount::wordcount(&c, &dv).0);

            // --- pagerank (paper tolerance 1e-5) ---
            let c = cluster(nodes, engine);
            rows.push(pagerank::pagerank(&c, &graph, 1e-5, 60).0);

            // --- k-means ---
            let c = cluster(nodes, engine);
            let blocks = kmeans::distribute_blocks(&c, &km_points, batch);
            let init = kmeans::init_first_k(&km_points, k);
            rows.push(
                kmeans::kmeans(
                    &c, &blocks, km_points.n, dim, k, init, 1e-4, 20, runtime.as_ref(),
                )
                .0,
            );

            // --- EM-GMM ---
            let c = cluster(nodes, engine);
            rows.push(
                gmm::gmm_from_points(&c, &gmm_points, k, 1e-6, 15, runtime.as_ref()).0,
            );

            // --- 100-NN ---
            let c = cluster(nodes, engine);
            rows.push(knn::knn(&c, &knn_points, &query, 100, runtime.as_ref()).0);

            // --- pi (eager engine only: Table 1 is Blaze vs hand code) ---
            if engine == EngineKind::Eager {
                let c = cluster(nodes, engine);
                rows.push(pi::pi_blaze(&c, 1_000_000 * scale as u64));
            }
        }
    }

    // ---- EXPERIMENTS.md-ready rows ----
    println!("== per-run rows (virtual makespans; paper metric = items/s/iter) ==");
    for row in &rows {
        println!("{}", row.line());
    }

    // ---- headline: Blaze vs conventional speedup per task per shape ----
    println!("\n== headline: Blaze speedup over conventional MapReduce ==");
    println!(
        "{:<10} {:>6} {:>14} {:>16} {:>9}",
        "task", "nodes", "blaze (it/s)", "conv (it/s)", "speedup"
    );
    let mut speedups: Vec<f64> = Vec::new();
    for &nodes in &node_counts {
        for task in ["wordcount", "pagerank", "kmeans", "gmm", "knn"] {
            let find = |engine: &str| {
                rows.iter()
                    .find(|r| r.task == task && r.nodes == nodes && r.engine == engine)
                    .expect("row")
            };
            let b = find("blaze");
            let c = find("conventional");
            let speedup = b.throughput / c.throughput;
            speedups.push(speedup);
            println!(
                "{:<10} {:>6} {:>14.0} {:>16.0} {:>8.1}x",
                task, nodes, b.throughput, c.throughput, speedup
            );
        }
    }
    let geo: f64 =
        (speedups.iter().map(|s| s.ln()).sum::<f64>() / speedups.len() as f64).exp();
    println!("\ngeometric-mean Blaze speedup: {geo:.1}x  (paper: >10x average)");

    // ---- scaling: throughput vs nodes for the eager engine ----
    println!("\n== Blaze scaling (throughput normalized to 1 node) ==");
    print!("{:<10}", "task");
    for &n in &node_counts {
        print!(" {n:>7}n");
    }
    println!();
    for task in ["wordcount", "pagerank", "kmeans", "gmm", "knn", "pi"] {
        let base = rows
            .iter()
            .find(|r| r.task == task && r.nodes == 1 && r.engine != "conventional")
            .map(|r| r.throughput)
            .unwrap_or(1.0);
        print!("{task:<10}");
        for &n in &node_counts {
            let t = rows
                .iter()
                .find(|r| r.task == task && r.nodes == n && r.engine != "conventional")
                .map_or(0.0, |r| r.throughput);
            print!(" {:>7.2}x", t / base);
        }
        println!();
    }
}
