//! K-Means over the PJRT-compiled JAX/Pallas assignment kernel
//! (paper §3.1.3) — the three-layer stack end to end on one workload.
//!
//! ```text
//! cargo run --release --example kmeans_train [n_points] [nodes]
//! ```
//!
//! Requires `make artifacts`. Falls back to the scalar mapper (with a
//! warning) if the artifacts are missing.

use blaze::apps::kmeans::{distribute_blocks, init_first_k, kmeans};
use blaze::data::PointSet;
use blaze::prelude::*;
use blaze::runtime::Runtime;

fn main() {
    let n: usize = std::env::args().nth(1).map_or(100_000, |s| s.parse().expect("n_points"));
    let nodes: usize = std::env::args().nth(2).map_or(4, |s| s.parse().expect("nodes"));

    let runtime = match Runtime::load("artifacts") {
        Ok(rt) => {
            println!("PJRT runtime: {rt:?}");
            Some(rt)
        }
        Err(e) => {
            eprintln!("warning: no artifacts ({e:#}); using scalar mappers");
            None
        }
    };
    let (dim, k) = runtime.as_ref().map_or((4, 5), |rt| (rt.dim(), rt.k()));
    let batch = runtime.as_ref().map_or(4096, Runtime::batch);

    let points = PointSet::clustered(n, dim, k, 0.6, 42);
    let cluster = Cluster::local(nodes, 4);
    let blocks = distribute_blocks(&cluster, &points, batch);
    let init = init_first_k(&points, k);

    let t0 = std::time::Instant::now();
    let (report, result) = kmeans(
        &cluster, &blocks, n, dim, k, init, 1e-4, 50, runtime.as_ref(),
    );
    println!(
        "{} points, k={k}, dim={dim}: converged in {} iterations, inertia {:.1}",
        n, result.iterations, result.inertia
    );
    println!(
        "virtual: {:.4}s makespan, {:.0} points/s/iter | host wall: {:.2}s",
        report.makespan_sec,
        report.throughput,
        t0.elapsed().as_secs_f64()
    );

    // Center recovery vs the generating mixture.
    let mut worst = 0.0f64;
    for tc in points.true_centers.chunks_exact(dim) {
        let best = result
            .centers
            .chunks_exact(dim)
            .map(|ec| {
                ec.iter()
                    .zip(tc)
                    .map(|(a, b)| f64::from(a - b).powi(2))
                    .sum::<f64>()
                    .sqrt()
            })
            .fold(f64::INFINITY, f64::min);
        worst = worst.max(best);
    }
    println!("worst center recovery error: {worst:.4}");
}
