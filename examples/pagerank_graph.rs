//! PageRank over a graph500 Kronecker graph (paper §3.1.2).
//!
//! ```text
//! cargo run --release --example pagerank_graph [scale] [nodes]
//! ```
//!
//! Generates a `2^scale`-vertex power-law graph, runs the paper's
//! three-MapReduce-per-iteration PageRank to the paper's 1e-5 convergence
//! criterion, and prints the top-ranked vertices plus the per-iteration
//! throughput (Fig 5's metric).

use blaze::apps::pagerank::{pagerank, pagerank_serial};
use blaze::data::Graph;
use blaze::prelude::*;

fn main() {
    let scale: u32 = std::env::args().nth(1).map_or(13, |s| s.parse().expect("scale"));
    let nodes: usize = std::env::args().nth(2).map_or(4, |s| s.parse().expect("nodes"));

    let graph = Graph::graph500(scale, 16, 42);
    println!(
        "graph500 scale={scale}: {} vertices, {} edges, {} sinks, max out-degree {}",
        graph.n_vertices,
        graph.n_edges(),
        graph.sinks().len(),
        graph.max_out_degree()
    );

    let cluster = Cluster::local(nodes, 4);
    let (report, result) = pagerank(&cluster, &graph, 1e-5, 100);
    println!(
        "converged in {} iterations (delta {:.2e}), {:.0} links/s/iter virtual",
        result.iterations, result.delta, report.throughput
    );

    // Validate against the serial oracle.
    let (oracle, _) = pagerank_serial(&graph, 1e-5, 100);
    let max_err = result
        .scores
        .iter()
        .zip(&oracle)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!("max |distributed - serial| = {max_err:.3e}");

    // Top 5 pages by rank (via the distributed topk).
    let ranked: DistVector<(f64, u32)> = DistVector::from_vec(
        &cluster,
        result.scores.iter().enumerate().map(|(v, &s)| (s, v as u32)).collect(),
    );
    println!("top pages:");
    for (score, v) in ranked.topk(5, |a, b| a.0.partial_cmp(&b.0).unwrap()) {
        println!("  vertex {v:>8}  score {score:.6}");
    }
    println!(
        "job: {:.4}s virtual makespan, {} B shuffled",
        report.makespan_sec, report.shuffle_bytes
    );
}
