//! Monte-Carlo π — the paper's Appendix A.2 example and Table 1 workload.
//!
//! ```text
//! cargo run --release --example pi [n_samples]
//! ```
//!
//! Runs the 8-line Blaze MapReduce version and the hand-optimized
//! MPI+OpenMP-style parallel loop side by side (Table 1's comparison).

use blaze::apps::pi::{pi_blaze, pi_hand_optimized, SLOC_BLAZE, SLOC_MPI_OPENMP};
use blaze::prelude::*;

fn main() {
    let n: u64 = std::env::args()
        .nth(1)
        .map(|s| s.parse().expect("sample count"))
        .unwrap_or(10_000_000);

    println!("samples = {n}\n");
    println!("{:<18} {:>12} {:>12} {:>8}", "implementation", "virtual(s)", "host(s)", "SLOC");
    for nodes in [1usize, 4] {
        let c = Cluster::local(nodes, 4);
        let blaze_report = pi_blaze(&c, n);
        let blaze_host = c.metrics().last_run().unwrap().host_wall_sec;
        let c2 = Cluster::local(nodes, 4);
        let hand_report = pi_hand_optimized(&c2, n);
        let hand_host = c2.metrics().last_run().unwrap().host_wall_sec;
        println!("--- {nodes} node(s) ---");
        println!(
            "{:<18} {:>12.4} {:>12.4} {:>8}",
            "blaze mapreduce", blaze_report.makespan_sec, blaze_host, SLOC_BLAZE
        );
        println!(
            "{:<18} {:>12.4} {:>12.4} {:>8}",
            "mpi+openmp loop", hand_report.makespan_sec, hand_host, SLOC_MPI_OPENMP
        );
        println!(
            "pi = {:.6} (blaze) / {:.6} (hand), ratio blaze/hand = {:.3}",
            blaze_report.result,
            hand_report.result,
            blaze_report.makespan_sec / hand_report.makespan_sec
        );
    }
}
