//! Streaming ingestion with skew, backpressure, and shard rebalancing.
//!
//! ```text
//! cargo run --release --example streaming_ingest [n_batches]
//! ```
//!
//! The data-pipeline scenario the Blaze containers serve between MapReduce
//! jobs: a stream of key/value batches with *drifting skew* is ingested
//! into a `DistHashMap` via repeated `mapreduce` calls (targets are merged
//! into, never cleared — paper §2.2), while the coordinator watches the
//! load imbalance and triggers slot rebalancing when it crosses a
//! threshold. Shuffle traffic flows through the bounded backpressure
//! window throughout.

use blaze::coordinator::rebalance::NUM_SLOTS;
use blaze::prelude::*;
use blaze::util::rng::SplitRng;

fn main() {
    let n_batches: usize =
        std::env::args().nth(1).map_or(12, |s| s.parse().expect("batch count"));
    let cluster = Cluster::local(8, 4);
    let mut table: DistHashMap<String, u64> = DistHashMap::new(&cluster);
    let mut rng = SplitRng::new(7, 0);
    let mut rebalances = 0usize;

    println!(
        "{:>6} {:>10} {:>12} {:>12} {:>12} {:>10}",
        "batch", "entries", "imbalance", "moved B", "shuffled B", "action"
    );
    for batch in 0..n_batches {
        // Drifting skew: each phase hammers a different hot key prefix, so
        // the hash-slot load tilts over time.
        let hot = format!("hot{}", batch / 3);
        let events: Vec<(String, u64)> = (0..20_000)
            .map(|_| {
                if rng.uniform() < 0.4 {
                    (format!("{hot}-{}", rng.below(40)), 1)
                } else {
                    (format!("key{}", rng.below(50_000)), 1)
                }
            })
            .collect();
        let stream = DistVector::from_vec(&cluster, events);
        mapreduce(
            &stream,
            |_, kv: &(String, u64), emit| emit(kv.0.clone(), kv.1),
            "sum",
            &mut table,
        );
        let shuffled = cluster.metrics().last_run().map_or(0, |r| r.shuffle_bytes);

        // Coordinator policy: rebalance when node loads tilt past 25%.
        let imb = table.imbalance();
        let (moved, action) = if imb > 1.25 {
            let plan = table.rebalance();
            rebalances += 1;
            (plan.cost_bytes(), format!("rebalance ({} slots)", plan.moves.len()))
        } else {
            (0, "-".to_string())
        };
        println!(
            "{:>6} {:>10} {:>12.3} {:>12} {:>12} {:>10}",
            batch,
            table.len(),
            imb,
            moved,
            shuffled,
            action
        );
    }

    let final_imb = table.imbalance();
    println!(
        "\ningested {} unique keys over {n_batches} batches; {} rebalances; final imbalance {final_imb:.3} ({} slots)",
        table.len(),
        rebalances,
        NUM_SLOTS
    );
    assert!(final_imb < 1.5, "coordinator failed to keep the table balanced");
}
